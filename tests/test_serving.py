"""Tenant-isolation conformance for the multi-tenant serving engine.

The headline invariant of ``repro.serve.protocol_engine``: putting a
protocol instance inside a shared-clock engine — where its Paillier
launches FUSE with other tenants' through the cross-tenant rows path —
must change NOTHING about what that tenant computes or observes.  The
matrix runs every registered workload family under the gold-batched,
vec, and adaptive cipher arms inside mixed 8-tenant engines and holds
each tenant to its solo ``run_on_runtime`` reference:

* RunReport core sections byte-identical (``diff_reports`` clean);
* per-iteration history bit-identical;
* the blinding rng consumed the exact same stream (post-run state
  parity), so solo and served runs stay interchangeable mid-protocol.

Property tests (via the ``_hypothesis_compat`` shim) fuzz random tenant
mixes — heterogeneous key sizes, staggered admission, mid-run
cancellation — and pin the structural guarantees: mismatched limb
widths NEVER fuse into one cluster, every fused result demuxes to the
tenant that submitted it, and fusing can only SAVE launches.  Churn
rides along: a quarter-schedule tenant keeps its churn telemetry and
recycled-update savings bit-identical to solo, and a tenant finishing
early (cancelled or short) must not perturb any surviving tenant's span
stream.  The admission tuner's knee detection, calibration-cache
round-trip, and corrupt-cache sequential fallback close the file.
"""
import dataclasses
import functools
import json
import random

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import workloads
from repro.core import paillier as gold
from repro.core import paillier_batch as pb
from repro.core import protocol
from repro.core.churn import ChurnSchedule
from repro.core.quantization import QuantSpec
from repro.data.synthetic import make_lasso
from repro.obs import metrics as obs_metrics
from repro.obs import trace as trace_mod
from repro.runtime import coalesce, dispatch
from repro.runtime.runner import build_runtime, collect_result, \
    run_on_runtime
from repro.runtime.scheduler import Scheduler
from repro.serve import protocol_engine as pe
from repro.serve.protocol_engine import ProtocolEngine

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")

SPEC = QuantSpec(delta=1e6, zmin=-8.0, zmax=8.0)
K, N, ITERS, KEY_BITS = 4, 32, 3, 128    # Nk = 8 == pb.BATCH_MIN
WORKLOADS = ("lasso", "ridge", "logistic", "elastic_net", "power_grid",
             "consensus_lasso", "consensus_logistic", "streaming_lasso")
ROW_SPLIT = {"consensus_lasso", "consensus_logistic"}
# adaptive runs price routing off a synthetic table (legacy device-
# wildcard keys), exactly as tests/test_conformance.py does
SYNTH_TABLE = {"version": 1, "entries": {
    f"gold/{KEY_BITS}/8": {"enc": 1e-6, "dec": 1e-6, "add": 1e-3,
                           "matvec": 1e-3, "convert": 1e-8},
    f"vec/{KEY_BITS}/8": {"enc": 1e-3, "dec": 1e-3, "add": 1e-6,
                          "matvec": 1e-6, "convert": 1e-8},
}}
ARMS = {
    "gold": dict(cipher="gold", gold_batch=True),
    "vec": dict(cipher="vec"),
    "adaptive": dict(cipher="auto"),
}


def _cfg(**kw):
    base = dict(K=K, lam=0.05, iters=ITERS, spec=SPEC, seed=0,
                key_bits=KEY_BITS)
    base.update(kw)
    return protocol.ProtocolConfig(**base)


@pytest.fixture(scope="module")
def inst():
    return make_lasso(24, N, sparsity=0.1, noise=0.01, seed=1)


def _workload_case(name, lasso_inst):
    """(workload, instance, spec, cfg overrides) — same grid as
    tests/test_conformance.py: every family's encrypted block is nk=8."""
    if name == "lasso":
        return None, lasso_inst, SPEC, {}
    wl = workloads.get_default(name)
    n = N // K if name in ROW_SPLIT else N
    winst = wl.make_instance(24, n, K, seed=1)
    spec = wl.calibrate_spec(winst.A, winst.y, K, ITERS)
    return wl, winst, spec, {"rho": wl.rho, "lam": wl.lam}


def _solo_run(A, y, cfg, wl=None, table=None):
    """Solo reference via the same build/collect split the engine uses,
    keeping the runtime handle so tests can inspect the box rng."""
    rt, master, w, mode = build_runtime(A, y, cfg, workload=wl, table=table)
    master.start()
    rt.sched.run()
    assert master.done
    return collect_result(rt, master, w, mode), rt


def _box_rng(rt):
    box = rt.box
    return box.gold.rng if isinstance(box, dispatch.AdaptiveBox) \
        else box.rng


# ---------------------------------------------------------------------------
# the conformance matrix: 8 families x {gold, vec, adaptive}, each arm a
# mixed 8-tenant engine held to per-family solo references
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=sorted(ARMS))
def served(request, inst):
    arm = request.param
    arm_kw = ARMS[arm]
    table = SYNTH_TABLE if arm == "adaptive" else None
    cases = {name: _workload_case(name, inst) for name in WORKLOADS}

    def case_cfg(name):
        wl, winst, spec, over = cases[name]
        return dataclasses.replace(_cfg(**arm_kw), workload=name,
                                   spec=spec, **over)

    solo = {}
    for name in WORKLOADS:
        wl, winst, _, _ = cases[name]
        solo[name] = _solo_run(winst.A, winst.y, case_cfg(name),
                               wl=wl, table=table)

    eng = ProtocolEngine(admission="concurrent")
    for name in WORKLOADS:
        wl, winst, _, _ = cases[name]
        eng.admit(winst.A, winst.y, case_cfg(name), tid=name,
                  workload=wl, table=table)
    results = eng.run()
    return {"arm": arm, "engine": eng, "results": results, "solo": solo}


def test_reports_bit_identical_to_solo(served):
    """Every tenant's RunReport core equals its solo reference byte for
    byte (modulo timing-only runtime telemetry), in a mixed engine where
    other tenants' ops share its launches."""
    for name in WORKLOADS:
        solo_res, _ = served["solo"][name]
        got = served["results"][name].stats
        assert obs_metrics.reports_equal_modulo_timing(got, solo_res.stats), \
            (served["arm"], name,
             obs_metrics.diff_reports(got, solo_res.stats))
        assert obs_metrics.validate_report_core(got) == []


def test_histories_bit_identical_to_solo(served):
    for name in WORKLOADS:
        solo_res, _ = served["solo"][name]
        assert np.array_equal(served["results"][name].history,
                              solo_res.history), (served["arm"], name)


def test_rng_consumption_identical_to_solo(served):
    """Fused launches replay each tenant's blinding draws from ITS OWN
    rng in submission order — the post-run stream position matches solo
    exactly."""
    for name in WORKLOADS:
        _, solo_rt = served["solo"][name]
        served_rt = served["engine"].tenants[name].rt
        assert _box_rng(served_rt).getstate() == \
            _box_rng(solo_rt).getstate(), (served["arm"], name)


def test_gold_arm_actually_fused(served):
    """The gold engine fused cross-tenant work (the matrix must not pass
    vacuously); vec/adaptive boxes ride the collector's solo path."""
    st_ = served["engine"].stats()["serve"]
    if served["arm"] == "gold":
        assert st_["fused_launches"] > 0
        assert st_["fused_ops"] > 0
    assert st_["tenants"] == len(WORKLOADS)
    for name in WORKLOADS:
        lat = st_["per_tenant"][name]["round_latency_s"]
        assert lat["n"] == ITERS and "p50" in lat and "p95" in lat


# ---------------------------------------------------------------------------
# property tests: heterogeneous key sizes at the queue level
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pool_key(bits: int) -> gold.PaillierKey:
    return gold.keygen(bits, random.Random(bits))


KEY_SIZES = (256, 512, 1024)


@given(st.data())
def test_mixed_key_sizes_fuse_safely(data):
    """Random tenant mixes over 256/512/1024-bit keys submitting ⊕ work:
    clusters never mix limb widths, every result demuxes to the right
    tenant's values, and the collector launches at most as often as the
    tenants would solo."""
    n_tenants = data.draw(st.integers(2, 4))
    specs = [(data.draw(st.sampled_from(KEY_SIZES)),
              data.draw(st.integers(2, 6)))
             for _ in range(n_tenants)]
    sched = Scheduler(seed=0)
    col = coalesce.CrossTenantCoalescer(sched)
    got: dict[int, list] = {}
    want: dict[int, list] = {}
    for i, (bits, n_ops) in enumerate(specs):
        key = _pool_key(bits)
        box = protocol.GoldBox(key, random.Random(i), batch=False,
                               counter=protocol.OpCounter())
        tq = coalesce.TenantQueue(sched, box, counter=box.counter,
                                  tenant=f"t{i}", collector=col)
        c1 = [gold.encrypt_crt(key, 10 + j, gold.rand_r(key, box.rng))
              for j in range(n_ops)]
        c2 = [gold.encrypt_crt(key, 20 + j, gold.rand_r(key, box.rng))
              for j in range(n_ops)]
        want[i] = [(a * b) % key.n2 for a, b in zip(c1, c2)]
        tq.submit("add", (c1, c2), functools.partial(
            lambda i, out: got.__setitem__(i, list(out)), i))
    sched.run()
    for i, (bits, _) in enumerate(specs):
        assert [int(x) for x in got[i]] == want[i], f"t{i} got wrong demux"
    # width safety: every fused cluster logged ONE limb width shared by
    # every rider (mismatched n^2 byte lengths must never co-launch)
    width_of = {f"t{i}": pb.rows_sig(_pool_key(b))[1]
                for i, (b, _) in enumerate(specs)}
    for entry in col.fused_log:
        assert {width_of[t] for t in entry["tenants"]} \
            == {entry["limb_bytes"]}, entry
    # fusing can only save launches: one per (op, width, op-count) solo
    solo_launches = len({(i, b, n) for i, (b, n) in enumerate(specs)})
    assert col.total_launches <= solo_launches
    assert col.fused_launches <= col.total_launches


# ---------------------------------------------------------------------------
# property tests: staggered admission + mid-run cancellation (engine level)
# ---------------------------------------------------------------------------

_PLAIN_SOLO_CACHE: dict = {}


def _plain_solo(A, y, seed: int, iters: int):
    k = (seed, iters)
    if k not in _PLAIN_SOLO_CACHE:
        _PLAIN_SOLO_CACHE[k] = run_on_runtime(
            A, y, _cfg(cipher="plain", K=2, seed=seed, iters=iters))
    return _PLAIN_SOLO_CACHE[k]


@functools.lru_cache(maxsize=1)
def _stagger_inst():
    # the shim's @given hides the wrapper signature from pytest, so this
    # property builds its instance itself instead of using the fixture
    return make_lasso(24, N, sparsity=0.1, noise=0.01, seed=1)


@given(st.data())
def test_staggered_admission_and_completion(data):
    """Tenants admitted at random offsets, some cancelled mid-run: each
    one's report equals a solo run of exactly the rounds it completed."""
    inst = _stagger_inst()
    A, y = inst.A[:, :16], inst.y
    n_tenants = data.draw(st.integers(2, 4))
    plan = []
    for i in range(n_tenants):
        iters = data.draw(st.integers(1, 3))
        cancel = data.draw(st.sampled_from((0, 1)))
        cancel_after = data.draw(st.integers(1, iters)) if cancel else None
        admit_at = data.draw(st.floats(0.0, 0.02))
        plan.append((i, iters, cancel_after, admit_at))
    eng = ProtocolEngine(admission="concurrent")
    for i, iters, cancel_after, admit_at in plan:
        eng.admit(A, y, _cfg(cipher="plain", K=2, seed=i, iters=iters),
                  tid=f"t{i}", admit_at=admit_at,
                  cancel_after=cancel_after)
    results = eng.run()
    per_tenant = eng.stats()["serve"]["per_tenant"]
    for i, iters, cancel_after, _ in plan:
        effective = iters if cancel_after is None \
            else min(iters, cancel_after)
        ref = _plain_solo(A, y, i, effective)
        got = results[f"t{i}"]
        assert obs_metrics.reports_equal_modulo_timing(
            got.stats, ref.stats), \
            (i, obs_metrics.diff_reports(got.stats, ref.stats))
        assert np.array_equal(got.history, ref.history)
        assert per_tenant[f"t{i}"]["rounds"] == effective
        assert per_tenant[f"t{i}"]["cancelled"] == (effective < iters)


# ---------------------------------------------------------------------------
# churn under serving
# ---------------------------------------------------------------------------

CHURN_ITERS = 5
CHURN = ChurnSchedule.quarter(K, CHURN_ITERS)


@pytest.mark.parametrize("arm_kw", [
    dict(cipher="plain", recycle=True),
    dict(cipher="gold", gold_batch=False, recycle=True),
], ids=["plain_recycle", "gold_recycle"])
def test_churn_tenant_matches_solo(inst, arm_kw):
    """A quarter-schedule churn tenant served next to a churn-free one
    keeps its leave/rejoin telemetry AND its recycled-update savings
    bit-identical to solo."""
    cfg_churn = _cfg(iters=CHURN_ITERS, churn=CHURN, **arm_kw)
    solo = run_on_runtime(inst.A, inst.y, cfg_churn)
    eng = ProtocolEngine(admission="concurrent")
    eng.admit(inst.A, inst.y, cfg_churn, tid="churny")
    eng.admit(inst.A, inst.y, _cfg(cipher=arm_kw["cipher"],
                                   gold_batch=False, seed=1), tid="steady")
    res = eng.run()
    got = res["churny"].stats
    assert obs_metrics.reports_equal_modulo_timing(got, solo.stats), \
        obs_metrics.diff_reports(got, solo.stats)
    assert got["churn"]["leaves"] == got["churn"]["rejoins"] == 1
    # lasso stalls after the rejoin, so tolerance-0 recycling saves real
    # crypto work — and saves exactly as much inside the engine
    assert got["churn"]["recycled"] == solo.stats["churn"]["recycled"] > 0
    assert np.array_equal(res["churny"].history, solo.history)


def test_finished_tenant_does_not_perturb_survivors(inst):
    """Determinism pin on the shared clock: tenant A's span stream is
    identical whether its neighbor B was cancelled after round 1 or
    simply configured with iters=1 — a tenant leaving the engine frees
    queue slots without touching anyone else's schedule."""
    A_, y_ = inst.A, inst.y

    def run_pair(b_iters, b_cancel):
        tr = trace_mod.Tracer()
        eng = ProtocolEngine(admission="concurrent")
        eng.admit(A_, y_, _cfg(cipher="gold", gold_batch=False),
                  tid="a", trace=tr)
        eng.admit(A_, y_, _cfg(cipher="gold", gold_batch=False, seed=1,
                               iters=b_iters),
                  tid="b", cancel_after=b_cancel)
        eng.run()
        return tr.signature()

    assert run_pair(ITERS, 1) == run_pair(1, None)


# ---------------------------------------------------------------------------
# admission tuner: knee detection + calibration cache
# ---------------------------------------------------------------------------

def test_knee_monotone_plateau_cliff():
    assert pe.knee([1, 2, 4, 8], [1.0, 2.0, 4.0, 8.0]) == 8
    assert pe.knee([1, 2, 4, 8], [1.0, 2.0, 2.1, 2.15]) == 2
    assert pe.knee([1, 2, 4], [1.0, 2.0, 0.5]) == 2
    assert pe.knee([4], [3.0]) == 4
    with pytest.raises(ValueError):
        pe.knee([], [])


def test_autotune_stops_past_the_knee():
    calls = []
    tput = {1: 1.0, 2: 2.0, 4: 2.05, 8: 100.0}

    def measure(w):
        calls.append(w)
        return tput[w]

    w, curve = pe.autotune(measure, (1, 2, 4, 8))
    assert w == 2
    assert calls == [1, 2, 4]        # 8 never measured: 4 already flat
    assert curve == {1: 1.0, 2: 2.0, 4: 2.05}


def test_serve_knee_cache_roundtrip(tmp_path):
    p = str(tmp_path / "calib.json")
    assert dispatch.load_serve_knee(KEY_BITS, 8, path=p) is None
    dispatch.save_serve_knee(KEY_BITS, 8, 16, curve={1: 3.0, 16: 9.5},
                             path=p)
    assert dispatch.load_serve_knee(KEY_BITS, 8, path=p) == 16
    # device-keyed: entries live under device_kind()/serve/bits/nk and
    # coexist with calibrate()'s per-backend entries
    doc = json.loads(open(p).read())
    key = f"{dispatch.device_kind()}/serve/{KEY_BITS}/8"
    assert doc["entries"][key]["window"] == 16
    doc["entries"]["cpu/gold/128/8"] = {"enc": 1e-4}
    open(p, "w").write(json.dumps(doc))
    dispatch.save_serve_knee(256, 8, 4, path=p)
    assert dispatch.load_serve_knee(KEY_BITS, 8, path=p) == 16
    assert dispatch.lookup(json.loads(open(p).read()), "gold", 128, 8,
                           device="cpu") == {"enc": 1e-4}


@pytest.mark.parametrize("corruption", [
    "not json {",
    json.dumps({"version": -1, "entries": {}}),
    json.dumps({"version": dispatch.TABLE_VERSION, "entries": []}),
    json.dumps({"version": dispatch.TABLE_VERSION,
                "entries": {"cpu/serve/128/8": {"window": 0}}}),
])
def test_corrupt_knee_cache_loads_none(tmp_path, corruption):
    p = tmp_path / "calib.json"
    p.write_text(corruption)
    assert dispatch.load_serve_knee(KEY_BITS, 8, path=str(p)) is None


def test_auto_admission_uses_cached_knee(inst, tmp_path):
    A_, y_ = inst.A[:, :16], inst.y
    p = str(tmp_path / "calib.json")
    dispatch.save_serve_knee(KEY_BITS, 8, 2, path=p)
    eng = ProtocolEngine(admission="auto", calib_path=p)
    for i in range(3):
        eng.admit(A_, y_, _cfg(cipher="plain", K=2, seed=i), tid=f"t{i}")
    eng.run()
    st_ = eng.stats()["serve"]
    assert st_["window"] == 2
    assert st_["auto_fallback_sequential"] is False


def test_auto_admission_falls_back_sequential_on_corrupt_cache(
        inst, tmp_path):
    A_, y_ = inst.A[:, :16], inst.y
    p = tmp_path / "calib.json"
    p.write_text("{corrupt")
    eng = ProtocolEngine(admission="auto", calib_path=str(p))
    for i in range(2):
        eng.admit(A_, y_, _cfg(cipher="plain", K=2, seed=i), tid=f"t{i}")
    res = eng.run()
    st_ = eng.stats()["serve"]
    assert st_["window"] == 1
    assert st_["auto_fallback_sequential"] is True
    # degraded admission, undamaged tenants
    for i in range(2):
        ref = _plain_solo(A_, y_, i, ITERS)
        assert obs_metrics.reports_equal_modulo_timing(
            res[f"t{i}"].stats, ref.stats)


# ---------------------------------------------------------------------------
# the multi-modulus rows layer itself (kb=128, two distinct keys fused)
# ---------------------------------------------------------------------------

def test_rows_ops_bit_exact_across_two_keys():
    k1 = gold.keygen(128, random.Random(7))
    k2 = gold.keygen(128, random.Random(8))
    rng1, rng2 = random.Random(0), random.Random(1)
    ms1, ms2 = [0, 1, 2**40, 999], [5, 6, 7]
    rs1 = [gold.rand_r(k1, rng1) for _ in ms1]
    rs2 = [gold.rand_r(k2, rng2) for _ in ms2]
    out1, out2 = pb.enc_rows([(k1, ms1, rs1), (k2, ms2, rs2)])
    assert out1 == [gold.encrypt_crt(k1, m, r) for m, r in zip(ms1, rs1)]
    assert out2 == [gold.encrypt_crt(k2, m, r) for m, r in zip(ms2, rs2)]
    d1, d2 = pb.dec_rows([(k1, out1), (k2, out2)])
    assert d1 == ms1 and d2 == ms2
    a1, = pb.add_rows([(k1, out1, out1)])
    assert a1 == [(c * c) % k1.n2 for c in out1]


def test_rows_mismatched_widths_raise():
    """The backstop below the collector's signature check: handing one
    cluster keys of different limb widths is a hard error, never a
    silent mis-launch."""
    k128 = gold.keygen(128, random.Random(7))
    k256 = gold.keygen(256, random.Random(9))
    with pytest.raises(ValueError, match="mismatched limb widths"):
        pb.enc_rows([(k128, [1], [2]), (k256, [1], [2])])


def test_serve_is_a_trace_category(inst):
    assert "serve" in trace_mod.CATEGORIES
    tr = trace_mod.Tracer()
    eng = ProtocolEngine(admission="sequential", trace=tr)
    eng.admit(inst.A[:, :16], inst.y, _cfg(cipher="plain", K=2), tid="t0")
    eng.run()
    cats = {s.cat for s in tr.spans}
    assert "serve" in cats
    names = {s.name for s in tr.spans if s.cat == "serve"}
    assert {"serve:admit:t0", "serve:start:t0", "serve:done:t0"} <= names
