"""repro.obs: RunReport conformance, span tracing, export, schema lint.

The load-bearing pin: both protocol drivers now build their stats through
``obs.metrics.build_run_report``, so a sync-mode pair must be EQUAL
MODULO TIMING — identical core sections (ops, traffic bytes, reshare
events, MSE trajectory) for every registered workload family on both the
plain and the gold cipher arm.  Everything else here covers the tracer
(span categories, determinism signature, zero-overhead null path), the
chrome-trace export + ``python -m repro.obs.report`` CLI, the OpCounter
phase-constant fixes, the ``timeit`` distribution upgrade, and the
``scripts/check_bench_schema`` artifact lint.
"""
from __future__ import annotations

import json
import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import BENCH_SCHEMA_VERSION, TimingResult, timeit
from repro import workloads
from repro.core import protocol
from repro.core.quantization import QuantSpec
from repro.data.synthetic import make_lasso
from repro.obs import chrome_trace, metrics, report as report_cli
from repro.obs import trace as trace_mod
from repro.runtime.runner import run_on_runtime
from scripts import check_bench_schema

SPEC = QuantSpec(delta=1e6, zmin=-8.0, zmax=8.0)
K, N, ITERS, KEY_BITS = 4, 32, 2, 128
WORKLOADS = ("lasso", "ridge", "logistic", "elastic_net", "power_grid",
             "consensus_lasso", "consensus_logistic", "streaming_lasso")
ROW_SPLIT = {"consensus_lasso", "consensus_logistic"}


def _case(name):
    """(workload, instance, spec, cfg overrides) — mirrors
    tests/test_conformance.py's setup so the same runs are compared."""
    if name == "lasso":
        return None, make_lasso(24, N, sparsity=0.1, noise=0.01,
                                seed=1), SPEC, {}
    wl = workloads.get_default(name)
    n = N // K if name in ROW_SPLIT else N
    winst = wl.make_instance(24, n, K, seed=1)
    spec = wl.calibrate_spec(winst.A, winst.y, K, ITERS)
    return wl, winst, spec, {"rho": wl.rho, "lam": wl.lam}


def _pair(name, cipher):
    wl, winst, spec, over = _case(name)
    kw = dict(K=K, lam=0.05, iters=ITERS, spec=spec, seed=0,
              key_bits=KEY_BITS, cipher=cipher, workload=name)
    kw.update(over)
    cfg = protocol.ProtocolConfig(**kw)
    rp = protocol.run_protocol(winst.A, winst.y, cfg, workload=wl)
    rr = run_on_runtime(winst.A, winst.y, cfg, workload=wl)
    return rp, rr


# ---------------------------------------------------------------------------
# RunReport conformance: both drivers, all families, plain + gold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", WORKLOADS)
@pytest.mark.parametrize("cipher", ("plain", "gold"))
def test_sync_run_reports_equal_modulo_timing(name, cipher):
    rp, rr = _pair(name, cipher)
    assert metrics.reports_equal_modulo_timing(rp.stats, rr.stats), \
        metrics.diff_reports(rp.stats, rr.stats, "protocol", "runtime")
    # and each is schema-valid with the right driver/runtime split
    assert metrics.validate_report_core(rp.stats) == []
    assert metrics.validate_report_core(rr.stats) == []
    assert rp.stats["driver"] == "protocol" and "runtime" not in rp.stats
    assert rr.stats["driver"] == "runtime" and "runtime" in rr.stats
    # the MSE trajectory is the shared convergence curve, ending at zero
    # (distance to the run's own final iterate)
    mse = rp.stats["mse_trajectory"]
    assert len(mse) == ITERS and mse[-1] == 0.0


def test_reshare_spans_match_reshare_events():
    """A streaming run's report records exactly ``reshare_events``
    re-share spans (and they all land in the iterate rounds)."""
    wl, winst, spec, over = _case("streaming_lasso")
    kw = dict(K=K, lam=0.05, iters=6, spec=spec, seed=0, cipher="plain",
              workload="streaming_lasso")
    kw.update(over)
    cfg = protocol.ProtocolConfig(**kw)
    tracer = trace_mod.Tracer()
    r = run_on_runtime(winst.A, winst.y, cfg, workload=wl, trace=tracer)
    assert r.stats["reshare_events"] > 0
    assert tracer.count("reshare") == r.stats["reshare_events"]
    sig_spans = [e for e in r.stats["runtime"]["trace"]
                 if e[1] == "reshare"]
    assert len(sig_spans) == r.stats["reshare_events"]


def test_secure_agg_rounds_traced():
    wl, winst, spec, over = _case("consensus_lasso")
    kw = dict(K=K, lam=0.05, iters=ITERS, spec=spec, seed=0,
              cipher="plain", workload="consensus_lasso")
    kw.update(over)
    cfg = protocol.ProtocolConfig(**kw)
    tracer = trace_mod.Tracer()
    run_on_runtime(winst.A, winst.y, cfg, workload=wl, trace=tracer)
    assert tracer.count("agg") == ITERS        # one aggregate per round


# ---------------------------------------------------------------------------
# tracer mechanics
# ---------------------------------------------------------------------------

def test_tracer_signature_excludes_wall_clock_only():
    a, b = trace_mod.Tracer(), trace_mod.Tracer()
    a.add("launch:enc", "launch", t=1.0, wall_ms=0.123, op="enc")
    b.add("launch:enc", "launch", t=1.0, wall_ms=9.876, op="enc")
    assert a.signature() == b.signature()
    b.add("x", "phase", t=2.0)
    assert a.signature() != b.signature()
    with pytest.raises(ValueError, match="category"):
        a.add("bad", "not-a-cat", t=0.0)


def test_null_tracer_is_default_and_inert():
    assert trace_mod.as_tracer(False) is trace_mod.NULL
    assert trace_mod.as_tracer(None) is trace_mod.NULL
    assert not trace_mod.NULL.enabled
    trace_mod.NULL.add("x", "phase", t=0.0)    # no-op, no error
    assert trace_mod.NULL.signature() == []
    t = trace_mod.Tracer()
    assert trace_mod.as_tracer(t) is t
    assert isinstance(trace_mod.as_tracer(True), trace_mod.Tracer)
    # untraced runs carry no trace key at all
    inst = make_lasso(12, 8, sparsity=0.2, noise=0.01, seed=0)
    cfg = protocol.ProtocolConfig(K=4, lam=0.05, iters=2, spec=SPEC,
                                  cipher="plain", seed=0)
    r = run_on_runtime(inst.A, inst.y, cfg)
    assert "trace" not in r.stats["runtime"]


# ---------------------------------------------------------------------------
# chrome-trace export + report CLI
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    inst = make_lasso(12, 8, sparsity=0.2, noise=0.01, seed=0)
    cfg = protocol.ProtocolConfig(K=4, lam=0.05, iters=3, spec=SPEC,
                                  cipher="plain", seed=0)
    tracer = trace_mod.Tracer()
    r = run_on_runtime(inst.A, inst.y, cfg, trace=tracer)
    path = tmp_path_factory.mktemp("obs") / "run.trace.json"
    chrome_trace.write(str(path), tracer, run_report=r.stats)
    return r, tracer, path


def test_chrome_trace_exports_loadable_doc(traced_run):
    r, tracer, path = traced_run
    doc = chrome_trace.load(str(path))
    assert chrome_trace.validate(doc, str(path)) == []
    events = doc["traceEvents"]
    x_events = [e for e in events if e.get("ph") == "X"]
    assert len(x_events) == len(tracer.spans)
    assert all(e["ts"] >= 0 and e["dur"] > 0 for e in x_events)
    # lane metadata present for every category in use
    lanes = {e["args"]["name"] for e in events
             if e.get("ph") == "M" and e.get("name") == "thread_name"}
    assert {s.cat for s in tracer.spans} <= lanes
    # lossless span list + embedded report round-trip
    spans = chrome_trace.load_spans(doc)
    assert len(spans) == len(tracer.spans)
    assert doc["runReport"]["workload"] == r.stats["workload"]
    assert metrics.validate_report_core(doc["runReport"]) == []


def test_report_cli_summary_and_diff(traced_run, tmp_path, capsys):
    _, _, path = traced_run
    assert report_cli.main([str(path)]) == 0
    out = capsys.readouterr().out
    for needle in ("workload=lasso", "phase", "coalesce:", "top spans:"):
        assert needle in out
    other = tmp_path / "b.trace.json"
    other.write_text(path.read_text())
    assert report_cli.main([str(path), str(other)]) == 0
    out = capsys.readouterr().out
    assert "equal modulo timing" in out


# ---------------------------------------------------------------------------
# OpCounter phase constants
# ---------------------------------------------------------------------------

def test_opcounter_unphased_bumps_are_not_miscounted():
    c = protocol.OpCounter()
    c.bump("enc", 2)                     # before any phase is set
    c.phase = protocol.PHASE_INIT
    c.bump("enc")
    d = c.as_dict()
    assert d[protocol.PHASE_UNSET] == {"enc": 2}
    assert d[protocol.PHASE_INIT] == {"enc": 1}


def test_opcounter_stable_key_order():
    c = protocol.OpCounter()
    for ph in (protocol.PHASE_ITERATE, "custom", protocol.PHASE_INIT):
        c.phase = ph
        c.bump("zop")
        c.bump("aop")
    keys = list(c.as_dict())
    assert keys == [protocol.PHASE_INIT, protocol.PHASE_ITERATE, "custom"]
    assert list(c.as_dict()[protocol.PHASE_INIT]) == ["aop", "zop"]
    assert protocol.PHASES == (protocol.PHASE_INIT, protocol.PHASE_SHARE,
                               protocol.PHASE_ITERATE)


# ---------------------------------------------------------------------------
# timing + metrics helpers
# ---------------------------------------------------------------------------

def test_timeit_returns_distribution_backward_compatible():
    calls = []
    t = timeit(lambda: calls.append(1), repeat=5, warmup=2)
    assert len(calls) == 7
    assert isinstance(t, TimingResult) and isinstance(t, float)
    assert float(t) == t.p50 and t.n == 5
    d = t.as_dict()
    assert set(d) == {"p50", "p95", "min", "mean", "n", "samples"}
    assert d["min"] <= d["p50"] <= d["p95"]
    assert 2.0 / t > 0                   # arithmetic still works


def test_metrics_summary_and_registry():
    s = metrics.summary(range(1, 101))
    assert s["n"] == 100 and s["min"] == 1 and s["max"] == 100
    assert s["p50"] == pytest.approx(50.5)
    assert s["p95"] == pytest.approx(95.05)
    assert metrics.summary([]) == {"n": 0}
    reg = metrics.Registry()
    reg.count("launches", 3)
    reg.gauge("depth", 7)
    reg.hist("wall").add(1.0)
    snap = reg.snapshot()
    assert snap["counters"] == {"launches": 3}
    assert snap["gauges"] == {"depth": 7.0}
    assert snap["histograms"]["wall"]["n"] == 1


def test_mse_trajectory_matches_history():
    h = np.array([[2.0, 0.0], [1.0, 1.0], [1.0, 0.0]])
    traj = metrics.mse_trajectory(h)
    assert traj == [pytest.approx(0.5), pytest.approx(0.5), 0.0]
    assert metrics.mse_trajectory(np.zeros((0, 4))) == []


# ---------------------------------------------------------------------------
# schema checker
# ---------------------------------------------------------------------------

def test_check_bench_schema_accepts_and_rejects(tmp_path, traced_run):
    _, _, trace_path = traced_run
    good = tmp_path / "BENCH_x.json"
    rp, _ = _pair("lasso", "plain")
    good.write_text(json.dumps({
        "schema_version": BENCH_SCHEMA_VERSION,
        "rows": [{"report": metrics.report_core(rp.stats)}]}))
    assert check_bench_schema.check_path(good) == []
    assert check_bench_schema.check_path(pathlib.Path(trace_path)) == []

    stale = tmp_path / "BENCH_stale.json"
    stale.write_text(json.dumps({"results": []}))
    assert any("schema_version" in e
               for e in check_bench_schema.check_path(stale))

    broken = json.loads(good.read_text())
    broken["rows"][0]["report"]["ops"] = "nope"
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text(json.dumps(broken))
    assert any("ops" in e for e in check_bench_schema.check_path(bad))

    bad_trace = tmp_path / "t.trace.json"
    doc = json.loads(pathlib.Path(trace_path).read_text())
    doc["traceEvents"].append({"ph": "X", "name": "x", "cat": "bogus",
                               "ts": 0, "dur": 1, "pid": 1, "tid": 1})
    bad_trace.write_text(json.dumps(doc))
    assert any("bogus" in e
               for e in check_bench_schema.check_path(bad_trace))

# ---------------------------------------------------------------------------
# report-core validation + diff edge cases
# ---------------------------------------------------------------------------

def _minimal_report():
    return metrics.build_run_report(
        driver="protocol", ops={"iterate": {"enc": 4}},
        traffic={"edge->master": 10}, key_bits=None, cipher="plain",
        workload="lasso", reshare_events=0,
        history=np.array([[1.0, 0.0], [0.0, 0.0]]))


def test_validate_report_core_rejections():
    good = _minimal_report()
    assert metrics.validate_report_core(good) == []

    wrong_version = dict(good, schema_version=99)
    assert any("schema_version" in e
               for e in metrics.validate_report_core(wrong_version))

    ill_ops = dict(good, ops={"iterate": {"enc": "four"}})
    errs = metrics.validate_report_core(ill_ops)
    assert any("ops['iterate']" in e for e in errs)
    ill_phase = dict(good, ops={"iterate": ["enc"]})
    assert any("ops['iterate']" in e
               for e in metrics.validate_report_core(ill_phase))

    bad_churn = dict(good, churn={"leaves": 1})          # missing keys
    assert any("churn" in e
               for e in metrics.validate_report_core(bad_churn))
    bad_churn2 = dict(good, churn={k: 0.5 for k in metrics.CHURN_KEYS})
    assert any("churn" in e
               for e in metrics.validate_report_core(bad_churn2))

    assert metrics.validate_report_core("nope") == ["report: not a dict"]
    for key in ("ops", "traffic_bytes", "mse_trajectory", "workload",
                "cipher"):
        broken = {k: v for k, v in good.items() if k != key}
        assert any(key in e
                   for e in metrics.validate_report_core(broken))


def test_diff_reports_asymmetric():
    a = _minimal_report()
    # one side carrying a runtime section is NOT a core difference
    b = dict(a, runtime={"virtual_time": 1.0})
    assert metrics.diff_reports(a, b) == []
    assert metrics.reports_equal_modulo_timing(a, b)
    # empty vs non-empty trajectory renders without crashing
    c = dict(a, mse_trajectory=[])
    lines = metrics.diff_reports(a, c, "A", "B")
    assert any("mse_trajectory" in line for line in lines)
    # dict-valued sections diff per-key
    d = dict(a, traffic_bytes={"edge->master": 11, "master->edge": 5})
    lines = metrics.diff_reports(a, d, "A", "B")
    assert any("traffic_bytes.edge->master" in line for line in lines)
    assert any("traffic_bytes.master->edge" in line for line in lines)


# ---------------------------------------------------------------------------
# report CLI: --json + the nonzero diff exit (CI gate)
# ---------------------------------------------------------------------------

def test_report_cli_json_and_diff_exit(traced_run, tmp_path, capsys):
    _, _, path = traced_run
    assert report_cli.main([str(path), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["kind"] == "summary"
    assert doc["core"]["workload"] == "lasso"
    assert doc["spans"] > 0
    assert "trace" not in (doc["runtime"] or {})

    # identical pair: exit 0; doctored core: exit 1 in both output modes
    same = tmp_path / "same.trace.json"
    same.write_text(path.read_text())
    assert report_cli.main([str(path), str(same)]) == 0
    capsys.readouterr()
    broken = json.loads(path.read_text())
    broken["runReport"]["traffic_bytes"]["edge->master"] += 1
    other = tmp_path / "b.trace.json"
    other.write_text(json.dumps(broken))
    assert report_cli.main([str(path), str(other)]) == 1
    assert "core sections differ" in capsys.readouterr().out
    assert report_cli.main([str(path), str(other), "--json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["kind"] == "diff" and doc["core_identical"] is False
    assert any("traffic_bytes" in line for line in doc["core_diff"])

    # a bare report on one side, no report on the other: also nonzero
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps(_minimal_report()))
    empty = tmp_path / "empty.trace.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert report_cli.main([str(bare), str(empty)]) == 1


def test_report_cli_renders_health_section(tmp_path, capsys):
    rep = _minimal_report()
    rep["health"] = {"alerts": [{"watcher": "mse_stall", "t": 1.0,
                                 "message": "no improvement"}],
                     "counters": {"rounds": 2}}
    p = tmp_path / "health.json"
    p.write_text(json.dumps(rep))
    assert report_cli.main([str(p)]) == 0
    out = capsys.readouterr().out
    assert "health: alerts=1" in out and "ALERT mse_stall" in out
    assert report_cli.main([str(p), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["health"]["counters"]["rounds"] == 2


# ---------------------------------------------------------------------------
# process-global profile log: the two-runs-one-process leak fix
# ---------------------------------------------------------------------------

def test_profile_log_drains_per_report():
    """Regression: sequential runs in one process each get ONLY their
    own profiling events — and sync-driver builds (no runtime section)
    still drain the log so it can't leak into a later runtime report."""
    metrics.profile_snapshot(clear=True)            # isolate from suite
    metrics.record_profile("warmup", op="enc")
    rt1: dict = {}
    metrics.build_run_report(
        driver="runtime", ops={}, traffic={}, key_bits=None,
        cipher="plain", workload="lasso", reshare_events=0,
        history=np.zeros((1, 2)), runtime=rt1)
    assert [e["kind"] for e in rt1["profile"]] == ["warmup"]

    rt2: dict = {}
    metrics.build_run_report(
        driver="runtime", ops={}, traffic={}, key_bits=None,
        cipher="plain", workload="lasso", reshare_events=0,
        history=np.zeros((1, 2)), runtime=rt2)
    assert rt2["profile"] == []                     # nothing leaked

    metrics.record_profile("calib", op="dec")
    _minimal_report()                               # sync build drains too
    rt3: dict = {}
    metrics.build_run_report(
        driver="runtime", ops={}, traffic={}, key_bits=None,
        cipher="plain", workload="lasso", reshare_events=0,
        history=np.zeros((1, 2)), runtime=rt3)
    assert rt3["profile"] == []


def test_profile_log_cap_and_overflow_marker():
    metrics.profile_snapshot(clear=True)
    for i in range(metrics.PROFILE_LOG_CAP + 5):
        metrics.record_profile("warmup", i=i)
    snap = metrics.profile_snapshot(clear=True)
    assert len(snap) == metrics.PROFILE_LOG_CAP + 1  # + overflow marker
    marker = snap[-1]
    assert marker["kind"] == "profile_overflow" and marker["dropped"] == 5
    # oldest events were the ones dropped
    assert snap[0]["i"] == 5
    # the drain reset the drop counter
    metrics.record_profile("warmup", i=0)
    snap2 = metrics.profile_snapshot(clear=True)
    assert [e["kind"] for e in snap2] == ["warmup"]
