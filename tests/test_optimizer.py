"""AdamW optimizer + schedule + mesh helpers."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.train import optimizer as opt


def test_schedule_shape():
    cfg = opt.OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(opt.schedule(jnp.asarray(s), cfg)) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-9          # linear warmup
    assert lrs[2] <= 1e-3 + 1e-9              # peak
    assert lrs[3] < lrs[2]                    # cosine decay
    assert lrs[4] >= 0.1 * 1e-3 * 0.999       # 10% floor


def test_adamw_descends_quadratic():
    cfg = opt.OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                        weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2.0 * params["w"]}      # d/dw ||w||^2
        params, state, m = opt.adamw_update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2
    assert m["grad_norm"] >= 0


def test_grad_clipping():
    cfg = opt.OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1,
                        total_steps=10)
    params = {"w": jnp.zeros(3)}
    state = opt.init_opt_state(params)
    big = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    p2, _, m = opt.adamw_update(big, state, params, cfg)
    # post-clip step is bounded by lr regardless of raw gradient size
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1.0
    assert float(m["grad_norm"]) > 1e5        # reported norm is pre-clip


def test_weight_decay_on_matrices_only():
    cfg = opt.OptConfig(lr=1e-2, weight_decay=1.0, warmup_steps=1,
                        total_steps=10, clip_norm=1e9)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    state = opt.init_opt_state(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = opt.adamw_update(zeros, state, params, cfg)
    assert float(p2["mat"][0, 0]) < 1.0        # decayed
    assert float(p2["vec"][0]) == 1.0          # not decayed


def test_mesh_helpers():
    from repro.launch import mesh as mesh_mod
    # function form never touches device state at import; helpers pure
    assert mesh_mod.dp_axes.__call__ is not None
    m = mesh_mod.make_mesh((1,), ("data",))
    assert mesh_mod.mesh_shape_dict(m) == {"data": 1}
    assert mesh_mod.dp_axes(m) == ("data",)
