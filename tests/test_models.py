"""Per-arch smoke tests: reduced config forward/train-step on CPU, shape and
NaN assertions, prefill/decode vs full-forward parity, mLSTM form
equivalence, MoE dispatch properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import registry, xlstm as xlstm_mod, layers as L
from repro.models import moe as moe_mod
from repro.train import loop as loop_mod
from repro.train.optimizer import OptConfig

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        b["prefix_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.n_prefix, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (B, 8, cfg.d_model)), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_reduced(arch)
    m = registry.get_model(cfg)
    params = m.init(cfg, KEY)
    batch = _batch(cfg)
    if cfg.family == "encdec":
        logits = m.forward(params, batch["tokens"], cfg,
                           frames=batch["frames"], use_scan=False)
    elif cfg.frontend == "vision":
        logits = m.forward(params, batch["tokens"], cfg,
                           prefix_embeds=batch["prefix_embeds"],
                           use_scan=False)
    else:
        logits = m.forward(params, batch["tokens"], cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_loss(arch):
    cfg = get_reduced(arch)
    step = loop_mod.make_train_step(cfg, OptConfig(lr=5e-3, warmup_steps=1,
                                                   total_steps=20),
                                    use_scan=False, remat=False)
    state = loop_mod.init_train_state(cfg, KEY)
    batch = _batch(cfg)
    jitted = jax.jit(step)
    losses = []
    for _ in range(8):
        state, metrics = jitted(state, batch)
        losses.append(float(metrics["loss"]))
        assert not np.isnan(losses[-1])
    assert losses[-1] < losses[0], losses   # overfits one batch


@pytest.mark.parametrize("arch", ["yi_9b", "qwen2_moe_a27b",
                                  "seamless_m4t_medium", "xlstm_125m",
                                  "recurrentgemma_2b"])
def test_prefill_decode_parity(arch):
    cfg = get_reduced(arch)
    m = registry.get_model(cfg)
    params = m.init(cfg, KEY)
    batch = _batch(cfg)
    tokens = batch["tokens"]
    kw = {"frames": batch["frames"]} if cfg.family == "encdec" else {}
    if cfg.family == "encdec":
        full = m.forward(params, tokens, cfg, use_scan=False, **kw)
    else:
        full = m.forward(params, tokens, cfg)
    cache = m.init_cache(cfg, B, S + 4)
    lg, cache = m.prefill(params, tokens, cfg, cache, **kw)
    assert float(jnp.max(jnp.abs(lg.reshape(B, -1) - full[:, -1]))) < 0.15
    nxt = jnp.argmax(full[:, -1], -1).astype(jnp.int32)
    lg2, _ = m.decode_step(params, nxt, cache, cfg)
    ext = jnp.concatenate([tokens, nxt[:, None]], 1)
    if cfg.family == "encdec":
        full2 = m.forward(params, ext, cfg, use_scan=False, **kw)
    else:
        full2 = m.forward(params, ext, cfg)
    assert float(jnp.max(jnp.abs(lg2 - full2[:, -1]))) < 0.15


def test_mlstm_parallel_equals_recurrent():
    """The two mLSTM forms must agree (training vs decode path)."""
    cfg = get_reduced("xlstm_125m")
    bp = xlstm_mod.init_block(KEY, cfg, 0)    # layer 0 = mLSTM
    rng = np.random.default_rng(0)
    di = int(cfg.proj_factor * cfg.d_model)
    xi = jnp.asarray(rng.normal(0, 0.5, (2, 10, di)), jnp.float32)
    par = xlstm_mod.mlstm_parallel(bp, xi, cfg)
    st = xlstm_mod.mlstm_init_state(cfg, 2)
    outs = []
    for t in range(10):
        o, st = xlstm_mod.mlstm_decode(bp, xi[:, t:t + 1], st, cfg)
        outs.append(o)
    rec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(par - rec)))
    assert err < 1e-4, err


def test_flash_attention_matches_naive():
    rng = np.random.default_rng(1)
    Bq, Sq, H, D, KV = 2, 256, 4, 32, 2
    q = jnp.asarray(rng.normal(0, 1, (Bq, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (Bq, Sq, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (Bq, Sq, KV, D)), jnp.float32)
    naive = L.attention_naive(q, k, v, causal=True)
    flash = L.attention_flash(q, k, v, causal=True, q_chunk=64, k_chunk=64)
    assert float(jnp.max(jnp.abs(naive - flash))) < 1e-4
    # windowed variant
    naive_w = L.attention_naive(q, k, v, causal=True, window=32)
    flash_w = L.attention_flash(q, k, v, causal=True, window=32,
                                q_chunk=64, k_chunk=64)
    assert float(jnp.max(jnp.abs(naive_w - flash_w))) < 1e-4


def test_moe_capacity_and_router():
    cfg = get_reduced("qwen2_moe_a27b")
    p = moe_mod.init_moe(KEY, cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 8, cfg.d_model)),
                    jnp.float32)
    out = moe_mod.moe_block(p, x, cfg)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))
    # capacity respects alignment
    assert moe_mod.capacity(cfg, 1024) % 8 == 0


def test_param_pspecs_divisibility():
    """Every sharded dim divides the production mesh axes (full configs)."""
    mesh_shape = {"data": 16, "model": 16}
    for arch in ARCHS:
        cfg = get_config(arch)
        m = registry.get_model(cfg)
        shapes = jax.eval_shape(lambda c=cfg, mm=m: mm.init(c, KEY))
        specs = registry.param_pspecs(cfg, shapes, mesh_shape)

        def check(leaf, spec):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is not None:
                    assert dim % mesh_shape[ax] == 0, (arch, leaf.shape, spec)
        jax.tree.map(check, shapes, specs,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_rope_rotation_invariant():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (1, 4, 2, 8)),
                    jnp.float32)
    pos = jnp.arange(4)[None]
    out = L.rope(x, pos, 10_000.0)
    # norm preserved per head position
    n_in = jnp.linalg.norm(x, axis=-1)
    n_out = jnp.linalg.norm(out, axis=-1)
    assert float(jnp.max(jnp.abs(n_in - n_out))) < 1e-4


def test_int8_kv_cache_decode_parity():
    """int8 KV cache (Gamma-style per-position scales) tracks bf16 decode."""
    from repro.models import transformer as T
    cfg = get_reduced("yi_9b")
    m = registry.get_model(cfg)
    params = m.init(cfg, KEY)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 0, cfg.vocab)
    cache = m.init_cache(cfg, B, 18)
    lg, cache = m.prefill(params, tokens, cfg, cache)
    nxt = jnp.argmax(lg.reshape(B, -1), -1).astype(jnp.int32)
    lg_bf16, _ = m.decode_step(params, nxt, cache, cfg)
    qc = T.init_cache(cfg, B, 18, quantized=True)
    for t in range(12):
        _, qc = m.decode_step(params, tokens[:, t], qc, cfg)
    lg_q, _ = m.decode_step(params, nxt, qc, cfg)
    assert float(jnp.max(jnp.abs(lg_q - lg_bf16))) < 0.25
